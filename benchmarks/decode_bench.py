"""Decode hot-path benchmark: segmented attention vs materialized concat.

The paper's premise is that decoding attends [Mem, cache] cheaply because
Mem(t) stays tiny — but the pre-segmented runtime rebuilt that
concatenation per layer per token (and fully dequantized int8 caches
before every attend).  This bench measures what the segmented attend
(`models.attention.attend_segments`) buys on the decode loop:

  concat    — `impl='concat'`: materialize [mem | cache | self] KV and
              KeyInfo every layer/step (the pre-PR baseline, kept as an
              explicit impl for exactly this comparison)
  segmented — the default in-place path: per-segment running-softmax,
              k-blocks past cache.length skipped, tile-wise int8 dequant

Scenarios: greedy-decode tokens/s vs occupied cache length at a fixed
cache capacity (serving arenas allocate Smax up front; decode cost must
scale with *occupancy*, not capacity), an int8-cache variant (in-kernel
tile dequant vs full-cache dequant), a VMAPPED-LANES scenario (a serve
batch of sessions at mixed cache occupancies: the lane-batched
custom_vmap route vs the legacy select-lowered vmap where every lane
computes capacity-bounded attention), and the serve engine's batched
query throughput.  Results are written to BENCH_decode.json (overwriting
any previous run) — the perf trajectory accumulates as one committed
snapshot per PR in git history, plus a smoke-run CI artifact per build.

Weights are random — decode throughput does not need a trained adapter.

    PYTHONPATH=src python benchmarks/decode_bench.py [--smoke] \
        [--out BENCH_decode.json]
"""
import argparse
import json
import sys
import time

sys.path.insert(0, ".")
sys.path.insert(0, "benchmarks")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import inference as I
from repro.models import transformer as T
from repro.serve import ServeEngine


def _filled_state(cfg, key, batch, smax, cache_len):
    """Online state with a cache filled to ``cache_len`` and a full
    memory — decode throughput needs realistic shapes, not a trained
    transcript, so the KV content is random."""
    st = I.init_online_state(cfg, batch, max_cache_len=smax)
    cache = st.cache
    if cfg.kv_cache_dtype == "int8":
        kq, ks = I.quantize_kv(jax.random.normal(key, cache.k_scale.shape
                                                 + (cfg.hd,), jnp.float32))
        vq, vs = I.quantize_kv(jax.random.normal(jax.random.fold_in(key, 1),
                                                 cache.v_scale.shape
                                                 + (cfg.hd,), jnp.float32))
        cache = cache._replace(k=kq, v=vq, k_scale=ks, v_scale=vs)
    else:
        cache = cache._replace(
            k=jax.random.normal(key, cache.k.shape, cache.k.dtype),
            v=jax.random.normal(jax.random.fold_in(key, 1), cache.v.shape,
                                cache.v.dtype))
    cache = cache._replace(length=jnp.asarray(cache_len, jnp.int32))
    mem = st.mem
    if mem is not None:
        mem = mem._replace(
            k=jax.random.normal(jax.random.fold_in(key, 2), mem.k.shape,
                                mem.k.dtype),
            v=jax.random.normal(jax.random.fold_in(key, 3), mem.v.shape,
                                mem.v.dtype),
            slots=jnp.asarray(mem.max_slots(cfg.ccm.comp_len), jnp.int32))
    return st._replace(cache=cache, mem=mem,
                       pos=jnp.asarray(cache_len, jnp.int32))


def make_decode_loop(params, cfg, impl, n_tokens):
    """Jitted greedy decode scan from a given state (what generate()'s
    decode phase runs) — the measured hot loop."""
    def run(state, tok):
        def step(carry, _):
            st, t = carry
            lg, st = I.decode_step(params, cfg, st, t, impl=impl)
            nt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return (st, nt), ()
        carry, _ = jax.lax.scan(step, (state, tok), None, length=n_tokens)
        return carry[0].cache.length, carry[1]
    return jax.jit(run)


def bench_decode(params, cfg, smax, cache_len, n_tokens, batch=1,
                 repeats=5):
    state = _filled_state(cfg, jax.random.PRNGKey(7), batch, smax,
                          cache_len)
    tok = jnp.zeros((batch, 1), jnp.int32)
    out = {}
    for impl in ("concat", "segmented"):
        fn = make_decode_loop(params, cfg,
                              None if impl == "segmented" else impl,
                              n_tokens)
        jax.block_until_ready(fn(state, tok))        # compile off-clock
        best = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(state, tok))
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        out[impl] = batch * n_tokens / best
    out["speedup"] = out["segmented"] / out["concat"]
    return out


def _stacked_lane_states(cfg, key, smax, lane_lens):
    """N independent single-session states (inner batch 1) stacked
    leaf-wise — the arena-gather layout session_vmap consumes — with a
    different cache occupancy per lane."""
    sts = [_filled_state(cfg, jax.random.fold_in(key, i), 1, smax, cl)
           for i, cl in enumerate(lane_lens)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *sts)


def make_lane_decode_loop(params, cfg, n_tokens):
    """Jitted greedy decode scan over a vmapped serve-style lane batch."""
    def run(state, tok):
        def step(carry, _):
            st, t = carry
            lg, st = jax.vmap(
                lambda s, tt: I.decode_step(params, cfg, s, tt))(st, t)
            nt = jnp.argmax(lg[:, :, -1], axis=-1).astype(jnp.int32)
            return (st, nt[..., None]), ()
        carry, _ = jax.lax.scan(step, (state, tok), None, length=n_tokens)
        return carry[0].cache.length, carry[1]
    return jax.jit(run)


def bench_decode_lanes(params, cfg, smax, lane_lens, n_tokens, repeats=9,
                       seg_block=None):
    """Vmapped serve lanes at mixed occupancies: lane-batched tile skip
    (cfg.attn_lane_batched=True, the default) vs the legacy vmap where
    the per-block skip `cond` lowers to a capacity-bound `select`.

    ``seg_block`` overrides ``cfg.attn_seg_block`` — the skip
    granularity.  Serve batches of small per-lane occupancies want finer
    blocks than the single-stream default (work rounds up to the block);
    the lane-batched path is insensitive to it (it folds ~1 block either
    way) while the select baseline's cost tracks capacity / block.

    The two variants are measured INTERLEAVED (one timed run of each per
    repeat): this container's clock drifts over long runs, and
    back-to-back variant blocks would credit the drift to whichever ran
    second."""
    if seg_block is not None:
        cfg = cfg.replace(attn_seg_block=seg_block)
    N = len(lane_lens)
    tok = jnp.zeros((N, 1, 1), jnp.int32)
    variants = {"select": cfg.replace(attn_lane_batched=False),
                "lane_batched": cfg}
    fns, states, best = {}, {}, {}
    for name, cfgv in variants.items():
        states[name] = _stacked_lane_states(cfgv, jax.random.PRNGKey(7),
                                            smax, lane_lens)
        fns[name] = make_lane_decode_loop(params, cfgv, n_tokens)
        jax.block_until_ready(fns[name](states[name], tok))  # compile
    for _ in range(repeats):
        for name in variants:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[name](states[name], tok))
            dt = time.perf_counter() - t0
            best[name] = min(best.get(name, dt), dt)
    out = {name: N * n_tokens / best[name] for name in variants}
    out["speedup"] = out["lane_batched"] / out["select"]
    return out


def bench_engine_query(params, cfg, n_sessions, qlen, cache_len):
    """Serve-engine batched query throughput (the vmapped prefill path —
    rides the same segmented attend)."""
    eng = ServeEngine(params, cfg, n_slots=n_sessions + 1,
                      cache_len=cache_len)
    toks = np.zeros(qlen, np.int32)
    for wave in ("warm", "run"):                      # warm compiles
        for s in range(n_sessions):
            eng.create_session(f"{wave}{s}")
        t0 = time.perf_counter()
        for s in range(n_sessions):
            eng.query(f"{wave}{s}", toks)
        eng.run()
        dt = time.perf_counter() - t0
        for s in range(n_sessions):
            eng.close_session(f"{wave}{s}")
    return n_sessions * qlen / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (trajectory artifact only)")
    ap.add_argument("--smax", type=int, default=4096,
                    help="allocated cache capacity (serving arena size)")
    ap.add_argument("--tokens", type=int, default=32,
                    help="decode tokens per measurement")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args()

    cfg = C.bench_cfg()          # 2 layers, d=128, 4q/2kv heads, f32
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    if args.smoke:
        smax, lens, n_tok = 512, (128, 256, 512), 8
    else:
        smax, lens, n_tok = args.smax, (256, 1024, 2048, args.smax), \
            args.tokens

    results = {"config": {"smax": smax, "n_tokens": n_tok,
                          "layers": cfg.n_layers, "d_model": cfg.d_model,
                          "smoke": bool(args.smoke)},
               "decode": [], "decode_int8": [], "engine": {}}
    print(f"\ndecode tokens/s at cache capacity Smax={smax} "
          f"({n_tok} greedy tokens, best of 5; 2-layer d=128 bench model)")
    print(f"{'cache_len':>10} {'concat':>10} {'segmented':>10} {'speedup':>8}")
    for cl in lens:
        r = bench_decode(params, cfg, smax, cl, n_tok)
        results["decode"].append({"cache_len": cl, **r})
        print(f"{cl:>10} {r['concat']:>10.1f} {r['segmented']:>10.1f} "
              f"{r['speedup']:>7.2f}x")
        C.csv_row(f"decode_seg_c{cl}", 1e6 / max(r["segmented"], 1e-9),
                  f"{r['speedup']:.2f}x vs concat")
        if cl >= 1024 and r["speedup"] < 2.0:
            print("WARNING: speedup below the 2x acceptance bar")

    short8 = (128, 256, 384, 256, 128, 512, 256, 128)
    if args.smoke:
        # seg_block 64 so even the tiny smoke capacity has blocks to skip
        lane_scenarios = {"mixed_short": ((64, 128, 64, 128), 64)}
        lane_tok = 4
    else:
        lane_scenarios = {
            # mostly-short serve batch at serve-tuned skip granularity
            # (small per-lane occupancies want finer blocks; the
            # lane-batched path folds ~1 block either way)
            "mixed_short": (short8, 256),
            # same batch at the single-stream default granularity
            "mixed_short_block512": (short8, 512),
            # one hot lane: lane-batched work is bounded by the batch max
            # on the jnp path (the Pallas lane grid skips per lane)
            "one_long": (short8[:-1] + (smax,), 256),
        }
        lane_tok = 64
    results["decode_lanes"] = []
    print(f"\nvmapped serve lanes at Smax={smax} "
          f"(lane-batched custom_vmap route vs select-lowered vmap)")
    print(f"{'scenario':>20} {'blk':>5} {'select':>10} {'lane_batched':>12} "
          f"{'speedup':>8}")
    for name, (lane_lens, blk) in lane_scenarios.items():
        r = bench_decode_lanes(params, cfg, smax, lane_lens, lane_tok,
                               seg_block=blk)
        results["decode_lanes"].append(
            {"scenario": name, "lane_lens": list(lane_lens),
             "seg_block": blk, **r})
        print(f"{name:>20} {blk:>5} {r['select']:>10.1f} "
              f"{r['lane_batched']:>12.1f} {r['speedup']:>7.2f}x")
        C.csv_row(f"decode_lanes_{name}",
                  1e6 / max(r["lane_batched"], 1e-9),
                  f"{r['speedup']:.2f}x vs select-lowered vmap")
        if name == "mixed_short" and not args.smoke and r["speedup"] < 1.5:
            print("WARNING: lane-batched speedup below the 1.5x bar")

    cfg8 = cfg.replace(kv_cache_dtype="int8")
    p8 = T.init_lm(jax.random.PRNGKey(0), cfg8)
    cl8 = lens[len(lens) // 2]
    r8 = bench_decode(p8, cfg8, smax, cl8, n_tok)
    results["decode_int8"].append({"cache_len": cl8, **r8})
    print(f"\nint8 cache (tile dequant vs full-cache dequant), "
          f"cache_len={cl8}:")
    print(f"{cl8:>10} {r8['concat']:>10.1f} {r8['segmented']:>10.1f} "
          f"{r8['speedup']:>7.2f}x")

    n_sess, qlen = (8, 4) if args.smoke else (32, 8)
    tps = bench_engine_query(params, cfg, n_sess, qlen,
                             cache_len=4 * qlen)
    results["engine"] = {"sessions": n_sess, "qlen": qlen,
                         "query_tokens_per_s": tps}
    print(f"\nengine batched query: {n_sess} sessions x {qlen} tokens "
          f"-> {tps:.0f} tok/s")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
