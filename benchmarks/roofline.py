"""§Roofline: derive the three roofline terms per (arch x shape x mesh)
from the dry-run's compiled artifacts (experiments/dryrun/*.json).

    compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective = collective_bytes_per_device / link_bw    (~50 GB/s/link)

(cost_analysis is per-device post-SPMD; `calibrated` entries are the
scan-trip-count-corrected values — see dryrun.calibrated_cost.)
Also reports MODEL_FLOPS = 6*N*D (6*N_active*D for MoE; x3 for the
fwd+bwd train step) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SHAPE_TOKENS = {   # global tokens processed per step
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n = rec["n_params_active"]
    d = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["shape"] == "train_4k" else 2.0   # fwd+bwd vs fwd
    if rec["shape"] == "train_4k" and "mode=lora" in (rec.get("note") or ""):
        mult = 4.0   # frozen base: fwd + activation-grad bwd, no wgrad
    return mult * n * d


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def roofline_terms(rec: Dict) -> Dict:
    cal = rec.get("calibrated") or {}
    flops = cal.get("flops") or rec["cost"].get("flops", 0.0)
    byts = cal.get("bytes") or rec["cost"].get("bytes accessed", 0.0)
    coll = cal.get("collective", rec.get("collective_total", 0.0))
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / LINK_BW
    dominant = max((t_c, "compute"), (t_m, "memory"),
                   (t_x, "collective"))[1]
    mf = model_flops(rec)
    chips = rec["devices"]
    hlo_global = flops * chips
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_bound_s": max(t_c, t_m, t_x),
        # fraction of the ideal compute-bound time actually achievable
        "roofline_fraction": (mf / chips / PEAK_FLOPS)
        / max(t_c, t_m, t_x) if max(t_c, t_m, t_x) > 0 else 0.0,
        "peak_gb": (rec["memory"]["peak_bytes"] or 0) / 1e9,
    }


def print_table(mesh: str = "single", dryrun_dir: str = DRYRUN_DIR,
                include_variants: bool = False):
    recs = [r for r in load_records(dryrun_dir) if r["mesh"] == mesh
            and (include_variants or not r.get("variant"))]
    hdr = (f"{'arch':<26} {'shape':<12} {'comp_s':>9} {'mem_s':>9} "
           f"{'coll_s':>9} {'dom':<10} {'useful':>7} {'roofl%':>7} "
           f"{'peakGB':>7}")
    print(hdr)
    print("-" * len(hdr))
    rows = {}
    for r in recs:
        t = roofline_terms(r)
        print(f"{r['arch']:<26} {r['shape']:<12} "
              f"{t['compute_s']:9.2e} {t['memory_s']:9.2e} "
              f"{t['collective_s']:9.2e} {t['dominant']:<10} "
              f"{t['useful_ratio']:7.2f} {100*t['roofline_fraction']:6.1f}% "
              f"{t['peak_gb']:7.2f}")
        rows[f"{r['arch']}/{r['shape']}"] = t
    return rows


def main():
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print_table(mesh)


if __name__ == "__main__":
    main()
