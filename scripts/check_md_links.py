#!/usr/bin/env python3
"""Markdown link check (no deps): every relative link/image target in the
repo's markdown docs must exist, and every in-page anchor must resolve.

    python scripts/check_md_links.py [files-or-dirs ...]

Defaults to README.md, ROADMAP.md and docs/.  External (http/mailto)
links are not fetched — CI stays hermetic.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def anchors(md: str):
    """GitHub-style slugs for every heading."""
    out = set()
    for h in HEADING.findall(md):
        slug = re.sub(r"[^\w\- ]", "", h.strip().lower())
        out.add(re.sub(r"\s+", "-", slug).strip("-"))
    return out


def check_file(path: Path, root: Path) -> list:
    errs = []
    md = path.read_text(encoding="utf-8")
    for target in LINK.findall(md):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, frag = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if not dest.exists():
            errs.append(f"{path.relative_to(root)}: broken link -> {target}")
            continue
        if frag and dest.suffix == ".md":
            if frag not in anchors(dest.read_text(encoding="utf-8")):
                errs.append(f"{path.relative_to(root)}: missing anchor "
                            f"-> {target}")
    return errs


def main(argv) -> int:
    root = Path(__file__).resolve().parent.parent
    args = [Path(a) for a in argv] or [root / "README.md", root / "ROADMAP.md",
                                       root / "docs"]
    files = []
    for a in args:
        files += sorted(a.rglob("*.md")) if a.is_dir() else [a]
    errs = []
    for f in files:
        errs += check_file(f.resolve(), root)
    for e in errs:
        print(f"ERROR: {e}")
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errs else 'ok'} ({len(errs)} broken)")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
