#!/usr/bin/env python
"""Lint: raw timers are confined to the observability clock module.

Everything under ``src/`` must take its timestamps from
`repro.obs.clock` (injectable — the deterministic simulation harness
swaps in a `ManualClock`); a stray ``time.time()`` / ``perf_counter()``
elsewhere silently reintroduces nondeterministic timing the obs layer
exists to remove.  This scans ``src/**/*.py`` for direct uses of the
stdlib timer functions (calls AND ``from time import ...`` aliases) and
fails listing each offender as ``file:line``.  ``benchmarks/``,
``examples/``, ``tests/`` and ``scripts/`` are intentionally out of
scope — drivers may time whatever they like.

Usage: python scripts/check_no_stray_timers.py [--root DIR]
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

# the one module allowed to touch the stdlib clock
ALLOWED = ("src/repro/obs/clock.py",)

TIMER_FNS = ("time", "perf_counter", "perf_counter_ns", "monotonic",
             "monotonic_ns", "process_time", "thread_time")
_CALL = re.compile(r"\btime\.(%s)\s*\(" % "|".join(TIMER_FNS))
_FROM = re.compile(r"^\s*from\s+time\s+import\b")


def scan(root: pathlib.Path):
    src = root / "src"
    offenders = []
    for path in sorted(src.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel in ALLOWED:
            continue
        for ln, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            code = line.split("#", 1)[0]      # ignore comments
            if _CALL.search(code) or _FROM.search(code):
                offenders.append((rel, ln, line.strip()))
    return offenders


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=".",
                    help="repo root containing src/ (default: cwd)")
    args = ap.parse_args(argv)
    offenders = scan(pathlib.Path(args.root))
    if offenders:
        print("stray timer calls outside repro.obs.clock "
              "(route them through the injectable clock):")
        for rel, ln, text in offenders:
            print(f"  {rel}:{ln}: {text}")
        return 1
    print("timer lint OK: all src/ timing goes through repro.obs.clock")
    return 0


if __name__ == "__main__":
    sys.exit(main())
