#!/usr/bin/env python
"""Serve-metrics CLI: drive a seeded engine workload and print (or
re-render) its metrics snapshot.

Two modes:

  default          — build a tiny `ServeEngine` (real model weights,
                     seeded traffic with offload churn + a backpressured
                     tenant), run it, and emit the metrics snapshot
  --from-json SNAP — skip the engine: re-render a previously saved
                     ``metrics_snapshot()["metrics"]`` JSON file (e.g.
                     the CI artifact from serve_bench --metrics-out)

Output formats (``--format``): ``json`` (the full snapshot, including
the derived ratios block) or ``prometheus`` (text exposition of the
registry).  ``--out FILE`` writes instead of printing.

    PYTHONPATH=src python scripts/serve_metrics.py --format prometheus
    PYTHONPATH=src python scripts/serve_metrics.py \
        --from-json serve_metrics.json --format prometheus

See docs/OBSERVABILITY.md for the metric catalog.
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")


def demo_engine():
    """Small seeded workload exercising every instrumented path:
    batching, padding waste, offload/restore churn, admission
    backpressure + pump, request tracing, and (n_shards=2) the
    per-shard gauge/counter labels of the sharded serve path."""
    import jax
    import numpy as np

    from repro.models import transformer as T
    from repro.models.config import CCMConfig, ModelConfig
    from repro.obs import Observability
    from repro.serve import ServeEngine, TenantQuota

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      ccm=CCMConfig(comp_len=2, max_steps=4))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        params, cfg, n_slots=4, max_resident=3, cache_len=64,
        n_shards=2, batch_buckets=(1, 2, 4), admission_policy="block",
        max_queued_tokens=64,
        tenant_quotas={"small": TenantQuota(max_queued_tokens=16)},
        obs=Observability.tracing())
    rng = np.random.RandomState(0)
    for s in range(6):
        eng.create_session(f"u{s}", tenant="small" if s >= 4 else "default")
    for rnd in range(6):
        for s in range(6):
            ln = (3, 5, 8)[rng.randint(3)]
            toks = rng.randint(0, cfg.vocab_size, size=ln).astype(np.int32)
            eng.ingest(f"u{s}", toks, priority=int(rng.randint(2)))
        eng.run(max_batches=2)
    for s in range(6):
        eng.query(f"u{s}", np.arange(4, dtype=np.int32))
    eng.run()
    return eng


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--format", choices=("json", "prometheus"),
                    default="json")
    ap.add_argument("--out", default=None,
                    help="write to a file instead of stdout")
    ap.add_argument("--from-json", default=None, metavar="SNAP",
                    help="re-render a saved snapshot JSON instead of "
                         "running the demo engine")
    args = ap.parse_args(argv)

    if args.from_json:
        with open(args.from_json) as f:
            snap = json.load(f)
        metrics = snap.get("metrics", snap)   # accept bare registry dicts
        if args.format == "prometheus":
            from repro.obs import render_prometheus
            text = render_prometheus(metrics)
        else:
            text = json.dumps(snap, indent=1)
    else:
        eng = demo_engine()
        if args.format == "prometheus":
            text = eng.metrics_prometheus()
        else:
            text = json.dumps(eng.metrics_snapshot(), indent=1)

    if args.out:
        with open(args.out, "w") as f:
            f.write(text if text.endswith("\n") else text + "\n")
        print(f"wrote {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
