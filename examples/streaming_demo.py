"""Unbounded-stream demo (paper Fig. 8/9): process an arbitrarily long
token stream with a FIXED KV budget — sliding window + attention sink,
evicted blocks compressed into CCM memory instead of dropped.

    PYTHONPATH=src python examples/streaming_demo.py --tokens 2048
"""
import argparse
import sys

sys.path.insert(0, ".")
sys.path.insert(0, "benchmarks")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import streaming as ST
from repro.data.synthetic import lm_stream
from repro.models.config import CCMConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()

    ccm = CCMConfig(comp_len=2, max_steps=4, stream_window=64,
                    stream_sink=4, stream_chunk=16, stream_mem_slots=8)
    cfg = C.bench_cfg().replace(ccm=ccm)
    print("training model + compression adapter...")
    base = C.pretrain_base(args.steps)
    params = C.train_compression(base, cfg, args.steps)

    toks = lm_stream(jax.random.PRNGKey(5), 4, args.tokens, cfg.vocab_size)
    for name, ccm_on in (("CCM streaming", True),
                         ("StreamingLLM (drop)", False)):
        st = ST.init_stream_state(cfg, 4)
        step = jax.jit(lambda s, t: ST.stream_step(params, cfg, s, t,
                                                   ccm_on=ccm_on))
        nll = cnt = 0.0
        for i in range(0, args.tokens - 16, 16):
            lg, st = step(st, toks[:, i:i + 16])
            lp = jax.nn.log_softmax(lg.astype(jnp.float32)[:, :-1], -1)
            tgt = toks[:, i + 1:i + 16]
            nll += float(-jnp.take_along_axis(lp, tgt[..., None], -1).sum())
            cnt += tgt.size
        kv_now = int(st.win_len) + int(st.mem.slots) * cfg.ccm.comp_len
        print(f"{name:22s}: {args.tokens} tokens streamed, "
              f"KV in use {kv_now} (budget {ccm.stream_window + ccm.stream_mem_slots*ccm.comp_len}), "
              f"ppl {np.exp(nll/cnt):.2f}")


if __name__ == "__main__":
    main()
