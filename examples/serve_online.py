"""Online serving demo (paper's conversation/personalization scenario):
user context arrives turn by turn, each turn is compressed into memory;
queries are served from [Mem, I(t)] with bounded KV.

    PYTHONPATH=src python examples/serve_online.py
"""
import sys
import time

sys.path.insert(0, ".")
sys.path.insert(0, "benchmarks")

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import inference as I
from repro.data.synthetic import sample_kv_batch


def main(steps: int = 250, turns: int = 4, users: int = 8):
    print("training serving model + compression adapter...")
    base = C.pretrain_base(steps)
    cfg = C.bench_cfg()
    params = C.train_compression(base, cfg, steps)

    layout = C.layout_for(turns)
    batch = sample_kv_batch(jax.random.PRNGKey(3), layout, users, C.TASK)
    toks = batch["tokens"]
    sl = layout.chunk_len + layout.comp_len

    ingest = jax.jit(lambda s, c: I.ingest_context(params, cfg, s, c))
    serve = jax.jit(lambda s, q: I.prefill(params, cfg, s, q,
                                           full_logits=True))

    state = I.init_online_state(cfg, users, max_cache_len=64)
    t_comp = 0.0
    for j in range(turns):
        chunk = toks[:, j * sl:(j + 1) * sl - layout.comp_len]
        t0 = time.perf_counter()
        state = jax.block_until_ready(ingest(state, chunk))
        t_comp += time.perf_counter() - t0
        raw_kv = C.kv_bytes(cfg, (j + 1) * layout.chunk_len) / 1024
        mem_kv = C.kv_bytes(cfg, int(state.mem.slots) * cfg.ccm.comp_len) \
            / 1024
        print(f"turn {j+1}: full-context KV would be {raw_kv:7.1f} KiB; "
              f"compressed memory is {mem_kv:5.1f} KiB")

    query = toks[:, turns * sl:]
    t0 = time.perf_counter()
    logits, _ = jax.block_until_ready(serve(state, query))
    t_q = time.perf_counter() - t0
    pred = jnp.argmax(logits[:, :-1], axis=-1)
    lm = batch["loss_mask"]
    acc = float(((pred == query[:, 1:]) * lm).sum() / lm.sum())
    print(f"\nserved {users} users: compress {t_comp*1e3:.0f} ms total, "
          f"query {t_q*1e3:.0f} ms, accuracy from memory {acc:.3f}")


if __name__ == "__main__":
    main()
