"""End-to-end training driver: CCM compression training with the full
production loop (checkpoint/restart, watchdog, deterministic data,
optional gradient compression).

    # CPU-sized default (~20M params, a few hundred steps):
    PYTHONPATH=src python examples/train_online.py --steps 200

    # ~100M-param configuration (TPU-sized; runs on CPU too, slowly):
    PYTHONPATH=src python examples/train_online.py --preset 100m --steps 300

    # any assigned architecture at smoke scale:
    PYTHONPATH=src python examples/train_online.py --arch qwen2-0.5b --smoke

    # conditional-LoRA ablation (paper Table 5):
    PYTHONPATH=src python examples/train_online.py --ablate-lora
"""
import argparse
import sys

sys.path.insert(0, ".")

from repro.configs.registry import get_config
from repro.core import masks as M
from repro.launch.train import TrainLoop
from repro.models.config import CCMConfig, ModelConfig
from repro.optim.adamw import AdamWConfig

PRESETS = {
    # ~20M params — minutes on this CPU
    "cpu": ModelConfig(
        name="ccm-20m", family="dense", n_layers=6, d_model=384, n_heads=6,
        n_kv_heads=2, d_ff=1024, vocab_size=8192, train_mode="lora",
        ccm=CCMConfig(comp_len=2, max_steps=4)),
    # ~100M params — the assignment's end-to-end scale (TPU-appropriate)
    "100m": ModelConfig(
        name="ccm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=16384,
        train_mode="lora", ccm=CCMConfig(comp_len=4, max_steps=8)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=list(PRESETS))
    ap.add_argument("--arch", default=None, help="assigned arch id")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/ccm_ckpt")
    ap.add_argument("--grad-codec", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ablate-lora", action="store_true",
                    help="compare conditional vs default LoRA")
    args = ap.parse_args()

    if args.arch:
        cfg = get_config(args.arch, smoke=args.smoke)
    else:
        cfg = PRESETS[args.preset]
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.1f}M  "
          f"train_mode={cfg.train_mode}")
    t, m = cfg.ccm.max_steps, max(cfg.ccm.comp_len, 1)
    layout = M.segment_layout(t, 12, m, 16)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps)

    if args.ablate_lora:
        from benchmarks import common as C
        base = C.pretrain_base(args.steps)
        for cond in (True, False):
            p = C.train_compression(base, C.bench_cfg(), args.steps,
                                    unconditional=not cond)
            acc = C.eval_at_timesteps(p, C.bench_cfg(), ts=(4,),
                                      unconditional=not cond)[4]
            print(f"{'conditional' if cond else 'default    '} LoRA "
                  f"acc@t4 = {acc:.3f}")
        return

    loop = TrainLoop(cfg, layout, opt, batch_size=args.batch,
                     ckpt_dir=args.ckpt, ckpt_every=50,
                     grad_codec=args.grad_codec)
    start = loop.maybe_restore()
    if start:
        print(f"resumed from checkpoint at step {start}")
    hist = loop.run(args.steps, start_step=start, log_every=20)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"(start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
