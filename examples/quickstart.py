"""Quickstart: train a compressed-context-memory adapter on a tiny model
and watch it answer queries whose evidence lives ONLY in compressed memory.

    PYTHONPATH=src python examples/quickstart.py

Steps: (1) fine-tune a tiny decoder full-context on the synthetic online
KV task, (2) train the conditional-LoRA compression adapter (paper Alg. 1),
(3) run ONLINE inference — contexts arrive chunk by chunk, are compressed
into <COMP> KV memory (raw KV discarded), then queries are answered from
memory alone. Compare against no-context accuracy.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

from benchmarks import common as C
from repro.core import inference as I
from repro.data.synthetic import sample_kv_batch
from repro.models import transformer as T


def main(steps: int = 300):
    print("1) fine-tuning base model (full context)...")
    base = C.pretrain_base(steps)
    cfg = C.bench_cfg()
    print("2) training CCM-concat compression adapter...")
    params = C.train_compression(base, cfg, steps)

    print("3) online inference with compressed context memory")
    layout = C.layout_for(C.T_MAX)
    batch = sample_kv_batch(jax.random.PRNGKey(7), layout, 4, C.TASK)
    toks = batch["tokens"]
    state = I.init_online_state(cfg, 4, max_cache_len=32)
    step = layout.chunk_len + layout.comp_len
    for j in range(layout.t_steps):
        chunk = toks[:, j * step:(j + 1) * step - layout.comp_len]
        state = I.ingest_context(params, cfg, state, chunk)
        raw = (j + 1) * layout.chunk_len
        comp = int(state.mem.slots) * cfg.ccm.comp_len
        print(f"   step {j+1}: context {raw:3d} tokens -> memory "
              f"{comp:2d} KV slots (compression {raw/comp:.1f}x)")
    tail = toks[:, layout.t_steps * step:]
    logits, _ = I.prefill(params, cfg, state, tail, full_logits=True)
    pred = jnp.argmax(logits[:, :-1], axis=-1)
    lm = batch["loss_mask"]
    acc = float(((pred == tail[:, 1:]) * lm).sum() / lm.sum())
    print(f"   query accuracy FROM MEMORY ONLY: {acc:.3f}")

    # no-context control
    lo0 = C.M.segment_layout(0, C.CHUNK, C.COMP, C.TAIL)
    plain = cfg.replace(ccm=cfg.ccm.__class__(enabled=False))
    lg0 = T.train_forward(base, plain, tail, lo0)
    pred0 = jnp.argmax(lg0[:, :-1], axis=-1)
    acc0 = float(((pred0 == tail[:, 1:]) * lm).sum() / lm.sum())
    print(f"   query accuracy WITHOUT context:  {acc0:.3f}")
    print("done — compressed memory carries the task information.")


if __name__ == "__main__":
    main()
