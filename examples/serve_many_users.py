"""Multi-tenant serving demo: many users, interleaved arrivals, bounded
device state.

Users arrive a few per round, each ingesting their context turn by turn
(compressed into CCM memory — never cached raw) and finally querying.
The serve engine continuously batches whatever mix of ops is pending
each round, packs the active sessions' arena rows into one jitted step,
and LRU-offloads cold sessions to host when the arena is smaller than
the user population — total users exceed device slots with no semantic
effect (offload->restore is bit-exact).  At the end one user's session
is forked into an agent tree: branches share the parent's compressed
memory copy-on-write and diverge with private turns.

    PYTHONPATH=src python examples/serve_many_users.py
"""
import argparse
import sys
import time

sys.path.insert(0, ".")
sys.path.insert(0, "benchmarks")

import jax
import numpy as np

from benchmarks import common as C
from repro.data.synthetic import sample_kv_batch
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--users", type=int, default=12)
    ap.add_argument("--turns", type=int, default=3)
    ap.add_argument("--slots", type=int, default=6,
                    help="arena slots (< users: forces LRU offload)")
    ap.add_argument("--arrivals", type=int, default=3,
                    help="new users per round")
    ap.add_argument("--metrics", action="store_true",
                    help="print the engine's Prometheus metrics export "
                         "at the end (docs/OBSERVABILITY.md)")
    args = ap.parse_args()

    print("training serving model + compression adapter...")
    base = C.pretrain_base(args.steps)
    cfg = C.bench_cfg()
    params = C.train_compression(base, cfg, args.steps)

    layout = C.layout_for(args.turns)
    batch = sample_kv_batch(jax.random.PRNGKey(3), layout, args.users,
                            C.TASK)
    toks = np.asarray(batch["tokens"])
    sl = layout.chunk_len + layout.comp_len

    eng = ServeEngine(params, cfg, n_slots=args.slots, cache_len=64)
    progress = {}          # user -> turns ingested so far
    queries = {}           # user -> pending query Request
    arrived = 0
    rnd = 0
    t0 = time.perf_counter()
    while len(queries) < args.users:
        rnd += 1
        for u in range(arrived, min(arrived + args.arrivals, args.users)):
            eng.create_session(f"u{u}")
            progress[u] = 0
        arrived = max(arrived, min(arrived + args.arrivals, args.users))
        for u, t in list(progress.items()):
            if t < args.turns:
                chunk = toks[u, t * sl:(t + 1) * sl - layout.comp_len]
                eng.ingest(f"u{u}", chunk)
                progress[u] = t + 1
            elif u not in queries:
                queries[u] = eng.query(
                    f"u{u}", toks[u, args.turns * sl:]).request
        eng.run()
        mgr = eng._mgr["online"]
        offloads = sum(s.n_offloads for s in mgr.sessions.values())
        print(f"round {rnd:2d}: {arrived:2d}/{args.users} users arrived, "
              f"{mgr.n_resident}/{args.slots} resident, "
              f"occupancy {eng.occupancy()['online']:.2f}, "
              f"{offloads} offloads so far")
    wall = time.perf_counter() - t0

    # forked agent tree: branch the first user's finished session
    # copy-on-write.  Both branches attach to u0's arena row for free
    # (refcount, no clone); each branch's first ingest breaks the share
    # with one jitted clone, so divergence costs exactly one row and
    # the parent never observes the branches' private turns.
    eng.fork_session("u0", "u0/a")
    eng.fork_session("u0", "u0/b")
    branches = {}
    for i, b in enumerate(("u0/a", "u0/b")):
        extra = toks[(i + 1) % args.users, :sl - layout.comp_len]
        eng.ingest(b, extra)                    # diverge: private turn
        branches[b] = eng.query(b, toks[0, args.turns * sl:]).request
    eng.run()
    parent = np.asarray(queries[0].result)
    diverged = [b for b, r in branches.items()
                if not np.allclose(np.asarray(r.result), parent)]
    print(f"\nforked u0 -> {sorted(branches)}: "
          f"{len(diverged)}/2 branches diverged from the parent "
          "(copy-on-write; u0's own state untouched)")

    lm = np.asarray(batch["loss_mask"])
    hits = tot = 0.0
    for u, req in queries.items():
        q = toks[u, args.turns * sl:]
        pred = np.argmax(req.result[:-1], axis=-1)
        hits += ((pred == q[1:]) * lm[u]).sum()
        tot += lm[u].sum()
    toks_done = sum(s["tokens"] for s in eng.stats.values())
    print(f"\nserved {args.users} users over {rnd} rounds in "
          f"{wall:.2f} s ({toks_done} tokens, "
          f"{toks_done / wall:.0f} tok/s incl. compile)")
    print(f"compiled programs: {eng.compile_stats()} "
          f"({eng.compiled_programs()} total)")
    occ = eng.batch_occupancy()
    print(f"batch occupancy: ingest {occ['ingest']:.2f}, "
          f"query {occ['query']:.2f} "
          "(ragged token buckets pad mixed-length requests into shared "
          "batches; pad lanes are masked)")
    print(f"accuracy from compressed memory: {hits / tot:.3f}")
    if args.metrics:
        print("\n--- metrics (Prometheus text exposition) ---")
        print(eng.metrics_prometheus())


if __name__ == "__main__":
    main()
